"""Mesh-batched scenario sweep: in-process multi-device tests.

These run the real multi-device code paths (no subprocess), so they need the
test process itself to have been started with

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m pytest tests/test_sharded_sweep.py

— which is exactly what the dedicated CI step does. Under a default
single-device run everything here skips; the multi-device contracts are still
covered in tier-1 via the subprocess test in ``test_sharded_core.py``.

The headline contract: ``sweep_sharded`` is bit-for-bit the single-device
``sweep_state_machine`` on any aligned mesh — event-sharded, and
event×scenario-sharded — because the per-round reductions go through the
canonical block partials of ``repro.core.segments`` (docs/SCALING.md).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AuctionRule, CounterfactualEngine, ScenarioGrid,
                        sweep_sharded, sweep_state_machine)
from repro.data import make_synthetic_env
from repro.launch.mesh import SweepMeshSpec

N_EVENTS = 4096
N_CAMPAIGNS = 16

needs_4_devices = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")


@pytest.fixture(scope="module")
def env():
    return make_synthetic_env(jax.random.PRNGKey(1), n_events=N_EVENTS,
                              n_campaigns=N_CAMPAIGNS, emb_dim=8)


def _grid(env):
    base = AuctionRule.first_price(N_CAMPAIGNS)
    return ScenarioGrid.product(base, env.budgets,
                                bid_scales=[1.0, 0.9, 1.1, 1.3],
                                reserves=[0.0, 0.05])


def _assert_bitwise(out, ref, label):
    names = ("final_spend", "cap_times", "retired", "boundaries",
             "num_rounds", "n_hat")
    for name, a, b in zip(names, out, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{label}: {name}")


@needs_4_devices
def test_event_sharded_sweep_bit_for_bit(env):
    """4 event-shard devices: every output of the batched loop is bitwise
    the single-device sweep's."""
    grid = _grid(env)
    ref = sweep_state_machine(env.values, grid.budgets, grid.rules,
                              resolve="jnp")
    spec = SweepMeshSpec.for_devices(num_event_devices=4)
    out = sweep_sharded(env.values, grid.budgets, grid.rules, spec)
    _assert_bitwise(out, ref, "event-sharded 4x1")


@needs_4_devices
def test_event_and_scenario_sharded_sweep_bit_for_bit(env):
    """2×2 mesh, events on "data" and scenarios on "model": still bitwise."""
    grid = _grid(env)
    ref = sweep_state_machine(env.values, grid.budgets, grid.rules,
                              resolve="jnp")
    spec = SweepMeshSpec.for_devices(num_event_devices=2,
                                     num_scenario_devices=2)
    assert spec.scenario_axis == "model"
    out = sweep_sharded(env.values, grid.budgets, grid.rules, spec)
    _assert_bitwise(out, ref, "event+scenario 2x2")


@needs_4_devices
def test_sharded_pallas_resolve_matches_batched(env):
    """driver-level resolve back-ends compose: the Pallas kernel (interpret
    mode on CPU) inside shard_map reproduces the jnp sharded sweep."""
    grid = _grid(env)
    spec = SweepMeshSpec.for_devices(num_event_devices=4)
    ref = sweep_sharded(env.values, grid.budgets, grid.rules, spec,
                        resolve="jnp")
    pal = sweep_sharded(env.values, grid.budgets, grid.rules, spec,
                        resolve="pallas", interpret=True)
    _assert_bitwise(pal, ref, "pallas vs jnp sharded")


@needs_4_devices
def test_sharded_fused_round_matches_batched(env):
    """resolve="fused" on the mesh: the two fused resolve+reduce passes per
    round psum the identical canonical partials, so the sharded fused sweep
    is bitwise the single-device jnp loop — with lane skipping on and off,
    and with the interpret-mode partials kernel forced."""
    grid = _grid(env)
    ref = sweep_state_machine(env.values, grid.budgets, grid.rules,
                              resolve="jnp")
    spec = SweepMeshSpec.for_devices(num_event_devices=4)
    for skip in (True, False):
        out = sweep_sharded(env.values, grid.budgets, grid.rules, spec,
                            resolve="fused", skip_retired=skip)
        _assert_bitwise(out, ref, f"fused sharded skip={skip}")
    out = sweep_sharded(env.values, grid.budgets, grid.rules, spec,
                        resolve="fused", interpret=True)
    _assert_bitwise(out, ref, "fused sharded interpret kernel")


@needs_4_devices
def test_sharded_fused_round_event_and_scenario_mesh(env):
    """Fused round on a 2×2 event×scenario mesh: still bitwise."""
    grid = _grid(env)
    ref = sweep_state_machine(env.values, grid.budgets, grid.rules,
                              resolve="jnp")
    spec = SweepMeshSpec.for_devices(num_event_devices=2,
                                     num_scenario_devices=2)
    out = sweep_sharded(env.values, grid.budgets, grid.rules, spec,
                        resolve="fused")
    _assert_bitwise(out, ref, "fused 2x2")


@needs_4_devices
def test_chunked_sharded_sweep_bit_for_bit(env):
    """chunking × sharding: each device scans its 1024-event shard in fixed
    chunks per round; the accumulated canonical partials psum to the exact
    single-device tensor, so every loop output stays bitwise."""
    grid = _grid(env)
    ref = sweep_state_machine(env.values, grid.budgets, grid.rules,
                              resolve="jnp")
    spec = SweepMeshSpec.for_devices(num_event_devices=4)
    for epc in (128, 256, 1024):
        out = sweep_sharded(env.values, grid.budgets, grid.rules, spec,
                            chunks=epc)
        _assert_bitwise(out, ref, f"chunked sharded epc={epc}")


@needs_4_devices
def test_chunked_sharded_fused_and_scenario_mesh(env):
    """Chunking composes with the fused back-end (per-chunk sweep_partials
    kernel passes) and with a 2×2 event×scenario mesh."""
    grid = _grid(env)
    ref = sweep_state_machine(env.values, grid.budgets, grid.rules,
                              resolve="jnp")
    spec = SweepMeshSpec.for_devices(num_event_devices=4)
    out = sweep_sharded(env.values, grid.budgets, grid.rules, spec,
                        resolve="fused", chunks=512)
    _assert_bitwise(out, ref, "chunked fused (oracle)")
    spec22 = SweepMeshSpec.for_devices(num_event_devices=2,
                                       num_scenario_devices=2)
    out = sweep_sharded(env.values, grid.budgets, grid.rules, spec22,
                        chunks=256)
    _assert_bitwise(out, ref, "chunked 2x2")


@needs_4_devices
def test_chunk_must_divide_local_shard(env):
    """A chunk size that is block-aligned but ragged against the per-device
    shard (1024 events) raises the pad-or-error contract."""
    grid = _grid(env)
    spec = SweepMeshSpec.for_devices(num_event_devices=4)
    with pytest.raises(ValueError, match="ragged chunk"):
        sweep_sharded(env.values, grid.budgets, grid.rules, spec,
                      chunks=768)


@needs_4_devices
def test_mesh_spec_plan_composes_chunking(env):
    """SweepMeshSpec.plan(...) builds the sharded plan with a chunk axis;
    executing it matches the wrapper entry point."""
    from repro.core import execute_sweep
    grid = _grid(env)
    spec = SweepMeshSpec.for_devices(num_event_devices=4)
    assert spec.local_event_count(N_EVENTS) == 1024
    plan = spec.plan(resolve="jnp", chunks=256)
    out = execute_sweep(env.values, grid.budgets, grid.rules, plan)
    ref = sweep_state_machine(env.values, grid.budgets, grid.rules,
                              resolve="jnp")
    _assert_bitwise(out, ref, "spec.plan chunked")


@needs_4_devices
def test_ragged_event_shard_raises(env):
    """N not divisible by the event-device count: explicit pad-or-error."""
    grid = _grid(env)
    spec = SweepMeshSpec.for_devices(num_event_devices=4)
    with pytest.raises(ValueError, match="ragged shard"):
        sweep_sharded(env.values[: N_EVENTS - 3], grid.budgets, grid.rules,
                      spec)   # 4093 events over 4 devices


@needs_4_devices
def test_misaligned_reduction_grid_raises(env):
    """N divisible by the device count but shards not holding whole canonical
    reduction blocks: the bit-for-bit contract cannot hold, so error."""
    grid = _grid(env)
    spec = SweepMeshSpec.for_devices(num_event_devices=4)
    with pytest.raises(ValueError, match="misalignment"):
        sweep_sharded(env.values[: N_EVENTS - 28], grid.budgets, grid.rules,
                      spec)   # 4068 events: shards of 1017, blocks of 128


@needs_4_devices
def test_ragged_scenario_shard_raises(env):
    base = AuctionRule.first_price(N_CAMPAIGNS)
    grid = ScenarioGrid.product(base, env.budgets,
                                bid_scales=[1.0, 1.1, 1.2])   # S=3
    spec = SweepMeshSpec.for_devices(num_event_devices=2,
                                     num_scenario_devices=2)
    with pytest.raises(ValueError, match="ragged scenario"):
        sweep_sharded(env.values, grid.budgets, grid.rules, spec)


@needs_4_devices
def test_engine_sweep_sharded_delta_table(env):
    """CounterfactualEngine.sweep(driver="sharded") reproduces the batched
    engine sweep end-to-end, delta table included."""
    engine = CounterfactualEngine(env.values, env.budgets)
    grid = engine.grid(bid_scales=[1.0, 1.1], budget_scales=[1.0, 0.5])
    spec = SweepMeshSpec.for_devices(num_event_devices=4)
    ref = engine.sweep(grid, method="parallel")
    out = engine.sweep(grid, method="parallel", driver="sharded", mesh=spec)
    np.testing.assert_array_equal(np.asarray(out.results.final_spend),
                                  np.asarray(ref.results.final_spend))
    np.testing.assert_array_equal(np.asarray(out.results.cap_times),
                                  np.asarray(ref.results.cap_times))
    assert out.delta_table() == ref.delta_table()


@needs_4_devices
def test_engine_sweep_sort2aggregate_sharded_warm_start(env):
    """The Algorithm-4 warm-start path on the mesh: sharded VI + sharded base
    refine + sharded per-scenario refine/aggregate converges to the same
    fixed point as the single-device s2a sweep (caps equal; spends equal up
    to psum regrouping — the aggregate pass is NOT under the canonical-grid
    bitwise contract, see docs/SCALING.md)."""
    engine = CounterfactualEngine(env.values, env.budgets)
    grid = engine.grid(bid_scales=[1.0, 1.15])
    spec = SweepMeshSpec.for_devices(num_event_devices=4)
    ref = engine.sweep(grid, method="sort2aggregate")
    out = engine.sweep(grid, method="sort2aggregate", driver="sharded",
                       mesh=spec)
    assert out.consistency_gaps is not None
    np.testing.assert_array_equal(np.asarray(out.results.cap_times),
                                  np.asarray(ref.results.cap_times))
    np.testing.assert_allclose(np.asarray(out.results.final_spend),
                               np.asarray(ref.results.final_spend),
                               rtol=1e-5, atol=1e-3)
    assert float(np.max(np.asarray(out.consistency_gaps))) == 0.0
