"""Metamorphic + golden tests for the scenario-family layer.

Three contracts lock :mod:`repro.scenarios` to the executor:

* **CRN metamorphics** — identical intervention specs produce bitwise
  identical lanes; adding scenarios to a family never changes other lanes'
  bits; results are invariant to scenario ordering and to every
  event/scenario chunk schedule (draws depend only on global (event,
  campaign) identity, never on lane index or execution layout).
* **Null identity** — a null intervention (full windows, sigma 0, prob 1)
  is bitwise the overlay-free base program under every placement × resolve
  × chunking combination, even on the per-event eligibility path.
* **Goldens** — a hand-computed 3-campaign / 8-event log where pausing and
  boosting reroute known auctions to known runners-up, including the
  Algorithm-2 capped case (predicted rate-based cap boundary vs the exact
  sequential crossing), and Shapley attribution satisfying the efficiency
  axiom exactly on a dyadic 2-axis grid.
"""
import functools

import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AuctionRule, CounterfactualEngine, ScenarioOverlay,
                        SweepPlan, execute_sweep, sequential_replay,
                        sweep_parallel, vi)
from repro.launch.mesh import SweepMeshSpec
from repro.scenarios import (AddEntrant, BidNoise, BoostCampaign,
                             BudgetPacing, MultiplierJitter,
                             ParticipationJitter, PauseCampaign, ScaleBids,
                             ScaleBudgets, SetReserve, compile_family,
                             shapley_values)

N, C = 512, 8


@functools.lru_cache(maxsize=1)
def _env():
    from repro.data import make_synthetic_env
    return make_synthetic_env(jax.random.PRNGKey(3), n_events=N,
                              n_campaigns=C, emb_dim=6)


def _engine():
    env = _env()
    return CounterfactualEngine(env.values, env.budgets,
                                AuctionRule.first_price(C))


def _spends_caps(swept):
    return (np.asarray(swept.results.final_spend),
            np.asarray(swept.results.cap_times))


# ---------------------------------------------------------------------------
# golden log: 3 campaigns x 8 events, all values dyadic (exact in float32)
# ---------------------------------------------------------------------------

GOLDEN_ROWS = [
    [.5, .75, .25], [.25, .5, .125], [.75, .25, .5], [.125, .75, .25],
    [.5, .25, .75], [.25, .5, .75], [.5, .25, .25], [.25, .5, .25],
]
# first price, reserve 0, budgets 10 (no caps): every event's winner and
# price are hand-readable off the rows; ties go to the lowest index.
GOLDEN_BASE_SPEND = [1.25, 2.5, 1.5]          # revenue 5.25
GOLDEN_PAUSE1_SPEND = [2.25, 0.0, 1.75]       # c1's 4 wins reroute; rev 4.0
GOLDEN_BOOST2_SPEND = [0.5, 2.5, 4.0]         # c2 x2 takes e2/e4/e5; rev 7.0
GOLDEN_PAUSE1_BOOST2_SPEND = [1.25, 0.0, 5.0]  # composed; revenue 6.25


def _golden_engine():
    values = jnp.asarray(GOLDEN_ROWS, jnp.float32)
    budgets = jnp.full((3,), 10.0, jnp.float32)
    return CounterfactualEngine(values, budgets, AuctionRule.first_price(3))


def test_golden_pause_reroutes_known_auctions():
    """Pausing c1 hands e0/e1 to c0, e3/e7 to the runner-up column —
    hand-computed final spends, exact in float (dyadic values)."""
    eng = _golden_engine()
    fam = compile_family(eng.values, eng.budgets, eng.base_rule,
                         [PauseCampaign(1)])
    swept = eng.sweep(fam)
    spend, caps = _spends_caps(swept)
    np.testing.assert_array_equal(spend[0], np.float32(GOLDEN_BASE_SPEND))
    np.testing.assert_array_equal(spend[1], np.float32(GOLDEN_PAUSE1_SPEND))
    assert float(swept.results.revenue[0]) == 5.25
    assert float(swept.results.revenue[1]) == 4.0
    # paused campaign: no spend, never caps
    assert spend[1, 1] == 0.0 and caps[1, 1] == 9


def test_golden_boost_and_composition():
    eng = _golden_engine()
    fam = compile_family(
        eng.values, eng.budgets, eng.base_rule,
        [BoostCampaign(2, 2.0), [PauseCampaign(1), BoostCampaign(2, 2.0)]])
    spend, _ = _spends_caps(eng.sweep(fam))
    np.testing.assert_array_equal(spend[1], np.float32(GOLDEN_BOOST2_SPEND))
    np.testing.assert_array_equal(spend[2],
                                  np.float32(GOLDEN_PAUSE1_BOOST2_SPEND))


def test_golden_capped_algorithm2_semantics():
    """With c1's budget at 1.0, the oracle caps c1 at event 2 (cumulative
    .75 + .5 crosses 1.0); Algorithm 2 predicts the cap from the round's
    spend *rate* (8 x 1.0/2.5 -> event 4). Final spends coincide exactly —
    the divergence is only in the predicted boundary, which is the
    documented Algorithm-2 contract, not a bug."""
    eng = _golden_engine()
    budgets = jnp.asarray([10.0, 1.0, 10.0], jnp.float32)
    fam = compile_family(eng.values, budgets, eng.base_rule, [])
    swept = CounterfactualEngine(eng.values, budgets,
                                 eng.base_rule).sweep(fam)
    spend, caps = _spends_caps(swept)
    oracle = sequential_replay(eng.values, budgets, eng.base_rule)
    np.testing.assert_array_equal(spend[0], np.float32([1.5, 1.25, 1.75]))
    np.testing.assert_array_equal(spend[0], np.asarray(oracle.final_spend))
    np.testing.assert_array_equal(caps[0], [9, 4, 9])
    np.testing.assert_array_equal(np.asarray(oracle.cap_times), [9, 2, 9])


def test_golden_entrant_takes_every_auction():
    """An entrant bidding 1.0 everywhere outbids every dyadic row: its lane
    spends 8.0 and every incumbent drops to 0; the base lane is untouched
    (the entrant's column exists but its window is empty)."""
    eng = _golden_engine()
    fam = compile_family(
        eng.values, eng.budgets, eng.base_rule,
        [AddEntrant(budget=10.0, values=np.ones(8, np.float32),
                    slot="newco")])
    assert fam.values.shape == (8, 4)
    spend, _ = _spends_caps(eng.sweep(fam))
    np.testing.assert_array_equal(spend[0],
                                  np.float32(GOLDEN_BASE_SPEND + [0.0]))
    np.testing.assert_array_equal(spend[1], np.float32([0, 0, 0, 8.0]))


def test_golden_shapley_efficiency_exact():
    """2-axis dyadic grid: phi_pause = -1.0, phi_boost = +2.0, summing
    EXACTLY (not approximately) to the total delta 6.25 - 5.25 = 1.0."""
    eng = _golden_engine()
    att = eng.attribute({"pause1": PauseCampaign(1),
                         "boost2": BoostCampaign(2, 2.0)})
    assert att.phi == {"pause1": -1.0, "boost2": 2.0}
    assert att.base_value == 5.25 and att.total_value == 6.25
    assert att.total_delta == 1.0
    assert att.efficiency_gap == 0.0
    assert "pause1" in att.format_table()


def test_shapley_values_unit():
    sv = shapley_values(("a", "b"), {frozenset(): 5.25,
                                     frozenset({"a"}): 4.0,
                                     frozenset({"b"}): 7.0,
                                     frozenset({"a", "b"}): 6.25})
    assert sv == {"a": -1.0, "b": 2.0}
    with pytest.raises(ValueError, match="missing"):
        shapley_values(("a", "b"), {frozenset(): 1.0})


def test_shapley_three_axes_efficiency():
    """3-axis attribution on the synthetic environment: 2^3 lattice swept
    in one program, efficiency within one float rounding."""
    eng = _engine()
    att = eng.attribute(
        {"boost": BoostCampaign(2, 1.5), "pause": PauseCampaign(5),
         "reserve": SetReserve(0.1)},
        key=jax.random.PRNGKey(11))
    assert len(att.subset_values) == 8
    assert att.efficiency_gap <= 1e-6 * max(1.0, abs(att.total_delta))


# ---------------------------------------------------------------------------
# null-intervention identity: bitwise the base program everywhere
# ---------------------------------------------------------------------------

def _null_overlay(s, c, key):
    """A null overlay that still exercises the per-event eligibility path:
    full windows, sigma 0, prob 1, time_varying=True."""
    return ScenarioOverlay(
        live_start=jnp.zeros((s, c), jnp.int32),
        live_stop=jnp.full((s, c), N, jnp.int32),
        bid_sigma=jnp.zeros((s, c), jnp.float32),
        part_prob=jnp.ones((s, c), jnp.float32),
        key=key, time_varying=True)


@pytest.mark.parametrize("resolve", ["jnp", "fused"])
@pytest.mark.parametrize("placement", ["device", "batched", "sharded"])
@pytest.mark.parametrize("chunking", [(None, None), (64, 1)])
def test_null_overlay_bitwise_base(placement, resolve, chunking):
    env = _env()
    epc, spc = chunking
    key = jax.random.PRNGKey(17)
    budgets = jnp.stack([env.budgets, env.budgets * 0.4])
    rules = AuctionRule(multipliers=jnp.ones((2, C), jnp.float32),
                       reserve=jnp.full((2,), 0.05, jnp.float32),
                       kind="first_price")
    if placement == "device":
        # one unbatched lane; the overlay's fields are (C,) rows — this is
        # the executor's device-placement expansion path
        plan = SweepPlan(placement="device", resolve=resolve, chunks=epc,
                         scenario_chunks=spc)
        rule1 = AuctionRule(multipliers=rules.multipliers[1],
                            reserve=rules.reserve[1], kind=rules.kind)
        row = ScenarioOverlay(
            live_start=jnp.zeros((C,), jnp.int32),
            live_stop=jnp.full((C,), N, jnp.int32),
            bid_sigma=jnp.zeros((C,), jnp.float32),
            part_prob=jnp.ones((C,), jnp.float32),
            key=key, time_varying=True)
        ref = execute_sweep(env.values, budgets[1], rule1, plan)
        out = execute_sweep(env.values, budgets[1], rule1, plan, overlay=row)
        for name, a, b in zip(("final_spend", "cap_times"), out[:2],
                              ref[:2]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
        return
    kwargs = dict(resolve=resolve, chunks=epc, scenario_chunks=spc)
    if placement == "sharded":
        kwargs.update(driver="sharded", mesh=SweepMeshSpec.for_devices())
    ref = sweep_parallel(env.values, budgets, rules, **kwargs)
    out = sweep_parallel(env.values, budgets, rules,
                         overlay=_null_overlay(2, C, key), **kwargs)
    np.testing.assert_array_equal(np.asarray(out.final_spend),
                                  np.asarray(ref.final_spend))
    np.testing.assert_array_equal(np.asarray(out.cap_times),
                                  np.asarray(ref.cap_times))


def test_null_interventions_compile_overlay_free():
    """Identity interventions (ScaleBids(1), full-log pacing) fold away at
    compile time and the lane is bitwise the base lane."""
    eng = _engine()
    fam = compile_family(
        eng.values, eng.budgets, eng.base_rule,
        [[ScaleBids(1.0), ScaleBudgets(1.0), BudgetPacing(3, 0, None)]])
    assert fam.overlay is None
    spend, caps = _spends_caps(eng.sweep(fam))
    np.testing.assert_array_equal(spend[1], spend[0])
    np.testing.assert_array_equal(caps[1], caps[0])


def test_zero_sigma_stochastic_lane_bitwise_base():
    """A family that is all-identity interventions folds to overlay=None at
    compile time; a sibling noisy lane forces the whole family onto the
    per-event CRN path, where the sigma=0 / prob=1 lane still must not move
    a single bit vs the base lane."""
    eng = _engine()
    folded = compile_family(
        eng.values, eng.budgets, eng.base_rule,
        [[BidNoise(0.0), ParticipationJitter(1.0)]],
        key=jax.random.PRNGKey(23))
    assert folded.overlay is None
    fam = compile_family(
        eng.values, eng.budgets, eng.base_rule,
        [[BidNoise(0.0), ParticipationJitter(1.0)], BidNoise(0.4)],
        key=jax.random.PRNGKey(23))
    assert fam.overlay is not None and fam.overlay.per_event
    spend, caps = _spends_caps(eng.sweep(fam))
    np.testing.assert_array_equal(spend[1], spend[0])
    np.testing.assert_array_equal(caps[1], caps[0])
    assert not np.array_equal(spend[2], spend[0])


def test_per_event_overlay_rejects_kernel_resolve():
    eng = _engine()
    fam = compile_family(eng.values, eng.budgets, eng.base_rule,
                         [BidNoise(0.3)], key=jax.random.PRNGKey(2))
    with pytest.raises(ValueError, match="jnp resolve path"):
        eng.sweep(fam, resolve="pallas")


def test_overlay_family_rejects_s2a():
    eng = _engine()
    fam = compile_family(eng.values, eng.budgets, eng.base_rule,
                         [PauseCampaign(0)])
    with pytest.raises(ValueError, match="parallel"):
        eng.sweep(fam, method="sort2aggregate")


def test_static_pause_overlay_runs_on_pallas_bitwise():
    """Empty-or-full windows fold into the activation mask, so the kernel
    back-ends stay eligible and bit-identical to jnp."""
    eng = _engine()
    fam = compile_family(eng.values, eng.budgets, eng.base_rule,
                         [PauseCampaign(2)])
    assert fam.overlay is not None and not fam.overlay.per_event
    ref = eng.sweep(fam, resolve="jnp")
    out = eng.sweep(fam, resolve="pallas")
    np.testing.assert_array_equal(*map(np.asarray, (out.results.final_spend,
                                                    ref.results.final_spend)))
    np.testing.assert_array_equal(*map(np.asarray, (out.results.cap_times,
                                                    ref.results.cap_times)))


# ---------------------------------------------------------------------------
# CRN metamorphic properties — a fixed deterministic panel of intervention
# specs; tests/test_scenarios_property.py re-runs the same metamorphics with
# hypothesis-randomized specs under the forced-multi-device CI step.
# ---------------------------------------------------------------------------

SPEC_PANEL = [
    (PauseCampaign(3),),
    (BoostCampaign(1, 1.7), BudgetPacing(4, start=128, stop=384)),
    (BidNoise(0.3), ParticipationJitter(0.8, campaign=2)),
    (BudgetPacing(0, start=65, stop=257), BidNoise(0.2, campaign=5),
     PauseCampaign(6)),
]


@pytest.mark.parametrize("spec", SPEC_PANEL)
@pytest.mark.parametrize("chunking", [(None, None), (64, 1), (128, 3)])
def test_crn_identical_specs_identical_lanes_any_chunking(spec, chunking):
    """The CRN contract's core metamorphic: the SAME intervention spec in
    two different lanes produces bitwise identical outcomes (draws depend
    on (event, campaign) identity, not the lane index), and the whole
    family is bitwise invariant under every aligned event/scenario chunk
    schedule."""
    eng = _engine()
    epc, spc = chunking
    fam = compile_family(eng.values, eng.budgets, eng.base_rule,
                         [spec, spec], key=jax.random.PRNGKey(5))
    ref = eng.sweep(fam)
    spend, caps = _spends_caps(ref)
    np.testing.assert_array_equal(spend[2], spend[1])
    np.testing.assert_array_equal(caps[2], caps[1])
    out = eng.sweep(fam, chunks=epc, scenario_chunks=spc)
    np.testing.assert_array_equal(np.asarray(out.results.final_spend),
                                  spend, err_msg=f"epc={epc} spc={spc}")
    np.testing.assert_array_equal(np.asarray(out.results.cap_times),
                                  caps, err_msg=f"epc={epc} spc={spc}")


@pytest.mark.parametrize("spec_a,spec_b",
                         list(zip(SPEC_PANEL, SPEC_PANEL[1:])))
def test_crn_delta_isolation_across_family_membership(spec_a, spec_b):
    """Adding a scenario to a family never changes any other lane's bits:
    lane outcomes depend only on (family key, own interventions), so
    deltas isolate the intervention by construction."""
    eng = _engine()
    key = jax.random.PRNGKey(5)
    fam_a = compile_family(eng.values, eng.budgets, eng.base_rule,
                           [spec_a], key=key)
    fam_ab = compile_family(eng.values, eng.budgets, eng.base_rule,
                            [spec_a, spec_b], key=key)
    sp_a, ct_a = _spends_caps(eng.sweep(fam_a))
    sp_ab, ct_ab = _spends_caps(eng.sweep(fam_ab))
    np.testing.assert_array_equal(sp_ab[:2], sp_a)
    np.testing.assert_array_equal(ct_ab[:2], ct_a)


@pytest.mark.parametrize("spec_a,spec_b",
                         list(zip(SPEC_PANEL, SPEC_PANEL[1:])))
def test_crn_scenario_order_independence(spec_a, spec_b):
    """Permuting the scenario list permutes the results bitwise — lane
    outcomes carry no trace of their scenario index."""
    eng = _engine()
    key = jax.random.PRNGKey(5)
    ab = compile_family(eng.values, eng.budgets, eng.base_rule,
                        [spec_a, spec_b], key=key)
    ba = compile_family(eng.values, eng.budgets, eng.base_rule,
                        [spec_b, spec_a], key=key)
    sp_ab, ct_ab = _spends_caps(eng.sweep(ab))
    sp_ba, ct_ba = _spends_caps(eng.sweep(ba))
    np.testing.assert_array_equal(sp_ab[1], sp_ba[2])
    np.testing.assert_array_equal(sp_ab[2], sp_ba[1])
    np.testing.assert_array_equal(ct_ab[1], ct_ba[2])


@pytest.mark.parametrize("c", [0, 5])
@pytest.mark.parametrize("extra", SPEC_PANEL[:3])
def test_pause_property(c, extra):
    """PauseCampaign(c) composed with other interventions: campaign c
    spends exactly 0 and never caps out."""
    eng = _engine()
    fam = compile_family(eng.values, eng.budgets, eng.base_rule,
                         [tuple(extra) + (PauseCampaign(c),)],
                         key=jax.random.PRNGKey(5))
    spend, caps = _spends_caps(eng.sweep(fam))
    assert spend[1, c] == 0.0
    assert caps[1, c] == N + 1


# ---------------------------------------------------------------------------
# compile_family contract
# ---------------------------------------------------------------------------

def test_design_only_family_compiles_overlay_free():
    eng = _engine()
    fam = compile_family(
        eng.values, eng.budgets, eng.base_rule,
        [BoostCampaign(1, 1.5), {"bid_scale": 1.2, "budget_scale": 0.5},
         MultiplierJitter(0.3, draw=1)],
        key=jax.random.PRNGKey(9))
    assert fam.overlay is None
    assert fam.labels[0] == "base"
    # and it runs on sort2aggregate, warm starts included
    swept = eng.sweep(fam, method="sort2aggregate",
                      warm_start="per_scenario")
    assert swept.results.final_spend.shape == (4, C)


def test_stochastic_family_requires_key():
    eng = _engine()
    with pytest.raises(ValueError, match="key"):
        compile_family(eng.values, eng.budgets, eng.base_rule,
                       [BidNoise(0.2)])


def test_campaign_bounds_checked():
    eng = _engine()
    with pytest.raises(ValueError, match="out of range"):
        compile_family(eng.values, eng.budgets, eng.base_rule,
                       [PauseCampaign(C)])


def test_entrant_slots_shared_by_label():
    """Two scenarios adding the SAME slot share one column (same CRN
    values); distinct slots get distinct columns."""
    eng = _engine()
    fam = compile_family(
        eng.values, eng.budgets, eng.base_rule,
        [AddEntrant(budget=3.0, slot="x"),
         [AddEntrant(budget=5.0, slot="x"), AddEntrant(budget=2.0,
                                                       slot="y")]],
        key=jax.random.PRNGKey(13))
    assert fam.num_entrants == 2
    assert fam.values.shape == (N, C + 2)
    # lane budgets reflect each scenario's own entrant budget
    b = np.asarray(fam.grid.budgets)
    assert b[1, C] == 3.0 and b[2, C] == 5.0 and b[2, C + 1] == 2.0
    # same family key => identical entrant value column across compiles
    fam2 = compile_family(eng.values, eng.budgets, eng.base_rule,
                          [AddEntrant(budget=1.0, slot="x")],
                          key=jax.random.PRNGKey(13))
    np.testing.assert_array_equal(np.asarray(fam.values[:, C]),
                                  np.asarray(fam2.values[:, C]))


def test_multiplier_jitter_draws_differ_but_replay_shared():
    eng = _engine()
    fam = compile_family(eng.values, eng.budgets, eng.base_rule,
                         [MultiplierJitter(0.2, draw=0),
                          MultiplierJitter(0.2, draw=1),
                          MultiplierJitter(0.2, draw=0)],
                         key=jax.random.PRNGKey(7))
    m = np.asarray(fam.grid.rules.multipliers)
    assert not np.array_equal(m[1], m[2])        # draws are i.i.d.
    np.testing.assert_array_equal(m[3], m[1])    # same draw = same design


# ---------------------------------------------------------------------------
# warm starts under the CRN jitter model (the re-measured satellite)
# ---------------------------------------------------------------------------

def test_warm_start_modes_on_crn_jitter_family():
    """All three warm-start modes converge to identical spends on a
    CRN-jittered design family, and the converged-base seed needs the
    fewest refine iterations per sweep (the re-measured ALGORITHMS.md
    recommendation; per_scenario's advantage is skipping the serial base
    pre-pass, not per-sweep iterations)."""
    eng = _engine()
    fam = compile_family(eng.values, eng.budgets, eng.base_rule,
                         [MultiplierJitter(1.0, draw=d) for d in range(6)],
                         key=jax.random.PRNGKey(7))
    assert fam.overlay is None
    runs = {ws: eng.sweep(fam, method="sort2aggregate", warm_start=ws,
                          refine_iters=24)
            for ws in ("base", "per_scenario", False)}
    base_spend = np.asarray(runs["base"].results.final_spend)
    for ws, swept in runs.items():
        np.testing.assert_array_equal(
            np.asarray(swept.results.final_spend), base_spend,
            err_msg=f"warm_start={ws} diverged")
        assert np.asarray(swept.consistency_gaps).max() == 0
    mean_iters = {ws: float(np.asarray(r.refine_iters).mean())
                  for ws, r in runs.items()}
    assert mean_iters["base"] <= mean_iters["per_scenario"]


# ---------------------------------------------------------------------------
# Algorithm 4 under overlays (CRN-keyed pi estimation)
# ---------------------------------------------------------------------------

def test_estimate_pi_sweep_with_overlay():
    """A paused campaign's pi goes to 1 (spends nothing, never caps); the
    estimate is deterministic given (key, overlay); the no-overlay path is
    untouched bitwise."""
    env = _env()
    S = 3
    budgets = jnp.broadcast_to(env.budgets, (S, C))
    rules = AuctionRule(multipliers=jnp.ones((S, C), jnp.float32),
                        reserve=jnp.zeros((S,), jnp.float32))
    ovl = ScenarioOverlay(
        live_start=jnp.zeros((S, C), jnp.int32),
        live_stop=jnp.full((S, C), N, jnp.int32).at[1, 0].set(0),
        bid_sigma=jnp.zeros((S, C), jnp.float32).at[2, 1].set(0.4),
        part_prob=None, key=jax.random.PRNGKey(2), time_varying=False)
    kw = dict(sample_size=64, num_iters=10, batch_size=16)
    est = vi.estimate_pi_sweep(env.values, budgets, rules,
                               jax.random.PRNGKey(0), overlay=ovl, **kw)
    pi = np.asarray(est.pi)
    assert pi.shape == (S, C)
    assert pi[1, 0] == 1.0                      # paused -> never caps
    est2 = vi.estimate_pi_sweep(env.values, budgets, rules,
                                jax.random.PRNGKey(0), overlay=ovl, **kw)
    np.testing.assert_array_equal(pi, np.asarray(est2.pi))
    # lanes 0 (no intervention) of overlay vs no-overlay runs agree bitwise
    est0 = vi.estimate_pi_sweep(env.values, budgets, rules,
                                jax.random.PRNGKey(0), **kw)
    np.testing.assert_array_equal(pi[0], np.asarray(est0.pi)[0])
