import os
import sys
from pathlib import Path

# src layout without install
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.

import jax  # noqa: E402

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
