"""Golden convergence + accounting contracts for the scenario-space search.

Two families:

* unit contracts on the search primitives — the box algebra of
  :class:`~repro.search.space.SearchSpace`, the charge-before-evaluate
  exactness of :class:`~repro.search.ledger.EvaluationLedger`, and the
  feasibility-first scoring of :mod:`repro.search.objectives`;
* golden convergence — a synthetic log whose revenue-maximizing reserve is
  known analytically, on which BOTH optimizers must land within tolerance
  while spending measurably fewer scenario evaluations than the exhaustive
  grid at the resolution they reached, with the evaluation ledger exact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AuctionRule, CounterfactualEngine
from repro.search import (BudgetExhausted, CapRateCeiling, EvaluationLedger,
                          SEARCH_METHODS, SearchSpace, as_objective,
                          coordinate_hillclimb, revenue_objective,
                          score_sweep, successive_halving)

# ---------------------------------------------------------------------------
# the golden log: revenue(r) is known in closed form
# ---------------------------------------------------------------------------

_GOLDEN_N, _GOLDEN_C = 512, 2
_R_STAR = 0.5          # argmax of r * #{v > r} for v ~ linspace(1/N, 1)
_R_TOL = 0.05


@pytest.fixture(scope="module")
def golden_engine():
    """Second-price log where campaign 0 bids ``linspace(1/N, 1)`` and
    campaign 1 never bids: with unconstrained budgets every sale is a
    single-eligible-bidder sale paying exactly the reserve, so

        revenue(r) = r * #{v > r}  ~=  N * r * (1 - r),

    maximized at the interior point r* = 1/2 — no budget dynamics, no
    ties, analytically checkable."""
    values = np.zeros((_GOLDEN_N, _GOLDEN_C), np.float32)
    values[:, 0] = np.linspace(1.0 / _GOLDEN_N, 1.0, _GOLDEN_N)
    budgets = np.full((_GOLDEN_C,), 1e9, np.float32)
    return CounterfactualEngine(
        jnp.asarray(values), jnp.asarray(budgets),
        base_rule=AuctionRule.second_price(_GOLDEN_C))


# ---------------------------------------------------------------------------
# SearchSpace
# ---------------------------------------------------------------------------

def test_space_requires_a_bounded_axis():
    with pytest.raises(ValueError, match="at least one bounded axis"):
        SearchSpace()
    with pytest.raises(ValueError, match="lo=0.4 > hi=0.1"):
        SearchSpace(reserve=(0.4, 0.1))


def test_space_grid_counts_and_bounds():
    s1 = SearchSpace(reserve=(0.0, 1.0))
    pts = s1.grid(7)
    assert len(pts) == 7                      # 1-D: exactly num points
    assert pts[0] == {"reserve": 0.0} and pts[-1] == {"reserve": 1.0}
    s2 = SearchSpace(reserve=(0.0, 1.0), budget_scale=(0.5, 2.0))
    pts2 = s2.grid(16)
    assert len(pts2) == 16                    # 2-D: 4x4 cartesian
    assert all(set(p) == {"reserve", "budget_scale"} for p in pts2)
    assert len(s2.grid(15)) == 9              # largest k**2 <= 15


def test_space_clip_and_shrink_stay_inside():
    s = SearchSpace(reserve=(0.1, 0.9))
    assert s.clip({"reserve": 5.0}) == {"reserve": 0.9}
    assert s.clip({}) == {"reserve": 0.5}     # missing axis -> box center
    # shrinking around an edge point slides inward, keeping full width
    box = s.shrink_around({"reserve": 0.1}, 0.25)
    lo, hi = box["reserve"]
    assert lo == pytest.approx(0.1) and hi - lo == pytest.approx(0.2)
    assert hi <= 0.9


def test_space_campaign_boost_axes():
    """Per-campaign boost[c] axes: declared via campaign_boost, ordered by
    campaign index, and handled by bounds/clip/grid exactly like the
    built-in axes."""
    s = SearchSpace(reserve=(0.0, 0.4), campaign_boost={3: (0.5, 2.0),
                                                        1: (0.9, 1.1)})
    assert s.axes == ("reserve", "boost[1]", "boost[3]")
    assert s.bounds()["boost[3]"] == (0.5, 2.0)
    assert "boost[7]" not in s.bounds()
    assert s.clip({"boost[3]": 9.0})["boost[3]"] == 2.0
    assert s.clip({})["boost[1]"] == pytest.approx(1.0)
    pts = s.grid(8)
    assert all(set(p) == set(s.axes) for p in pts)
    box = s.shrink_around(s.clip({}), 0.5)
    lo, hi = box["boost[3]"]
    assert 0.5 <= lo < hi <= 2.0
    with pytest.raises(ValueError, match="twice"):
        SearchSpace(campaign_boost=((2, (0.5, 2.0)), (2, (0.5, 2.0))))


def test_grid_from_points_boost_axis(golden_engine):
    """boost[c] points multiply exactly campaign c's multiplier on top of
    bid_scale; unknown axes are rejected."""
    grid = golden_engine.grid_from_points(
        [{"bid_scale": 1.0}, {"bid_scale": 2.0, "boost[0]": 3.0}])
    m = np.asarray(grid.rules.multipliers)
    np.testing.assert_allclose(m[1, 0], m[0, 0] * 6.0)
    np.testing.assert_allclose(m[1, 1], m[0, 1] * 2.0)
    assert "boost[0]×3" in grid.labels[1]
    with pytest.raises(ValueError, match="unknown grid axis"):
        golden_engine.grid_from_points([{"boost": 2.0}])


# ---------------------------------------------------------------------------
# EvaluationLedger
# ---------------------------------------------------------------------------

def test_ledger_exact_accounting():
    led = EvaluationLedger(budget=10)
    led.charge(4, "a")
    led.charge(6, "b")
    assert led.spent == 10 and led.remaining == 0
    assert [n for _, n in led.entries] == [4, 6]
    with pytest.raises(BudgetExhausted, match="evaluation budget exhausted"):
        led.charge(1, "c")
    assert led.spent == 10                    # failed charge records nothing
    with pytest.raises(ValueError):
        EvaluationLedger(budget=0)
    with pytest.raises(ValueError):
        led.charge(0)


def test_ledger_affordable_is_the_gate():
    led = EvaluationLedger(budget=5)
    assert led.affordable(5) and not led.affordable(6)


# ---------------------------------------------------------------------------
# objectives / constraints
# ---------------------------------------------------------------------------

def test_score_sweep_margins(golden_engine):
    swept = golden_engine.sweep(golden_engine.grid(reserves=[0.1, 0.5]))
    values, margins = score_sweep(swept, revenue_objective, ())
    assert values.shape == margins.shape == (2,)
    assert (margins == 0.0).all()             # unconstrained = feasible
    # no campaign caps out on the golden log -> cap-rate 0 <= any ceiling
    _, m = score_sweep(swept, as_objective("revenue"), (CapRateCeiling(0.1),))
    np.testing.assert_allclose(m, 0.1)
    with pytest.raises(ValueError, match="unknown objective"):
        as_objective("profit")


# ---------------------------------------------------------------------------
# golden convergence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", SEARCH_METHODS)
def test_search_finds_known_optimal_reserve(golden_engine, method):
    """Acceptance: both optimizers land within _R_TOL of the analytic
    optimum with measurably fewer evaluations than the exhaustive grid at
    the resolution the search reached, and the ledger is exact."""
    space = SearchSpace(reserve=(0.0, 1.0))
    res = golden_engine.search(space, method=method, budget=64)
    assert res.converged
    assert res.best_feasible
    assert abs(res.best_point["reserve"] - _R_STAR) < _R_TOL

    # ledger exactness: no silent over- or under-spend anywhere
    assert res.evaluations == res.ledger.spent \
        == sum(n for _, n in res.ledger.entries) \
        == sum(h["evaluations"] for h in res.history)
    assert res.evaluations <= 64

    # fewer evaluations than the exhaustive grid at the resolution the
    # search reached (xatol=1e-2 over a unit-width axis -> 101 points)
    k = 101
    grid = golden_engine.grid(reserves=list(np.linspace(0.0, 1.0, k)))
    assert res.evaluations < grid.num_scenarios // 2
    swept = golden_engine.sweep(grid)
    rev = np.asarray(swept.results.revenue)
    assert res.best_value >= rev.max() * 0.98  # and no worse an optimum


def test_search_over_boost_axis(golden_engine):
    """A per-campaign boost axis drives the same inner sweep: on a
    first-price log with unconstrained budgets, revenue is linear in
    campaign 0's boost, so the search must run to the axis' upper bound —
    and never step outside it."""
    eng = CounterfactualEngine(golden_engine.values, golden_engine.budgets,
                               AuctionRule.first_price(_GOLDEN_C))
    space = SearchSpace(campaign_boost={0: (0.5, 2.0)})
    res = eng.search(space, method="hillclimb", budget=64)
    assert res.converged
    assert 0.5 <= res.best_point["boost[0]"] <= 2.0
    assert res.best_point["boost[0]"] > 1.9
    assert res.evaluations == res.ledger.spent <= 64
    base_rev = float(np.asarray(
        eng.sweep(eng.grid_from_points([{}])).results.revenue)[0])
    assert res.best_value == pytest.approx(
        base_rev * res.best_point["boost[0]"], rel=1e-5)


def test_search_respects_constraints(golden_engine):
    """An unattainable constraint (every scenario violated) must steer
    selection by least violation, and report infeasibility instead of
    silently returning the unconstrained optimum."""
    def impossible(swept):
        rev = np.asarray(swept.results.revenue, np.float64)
        return -1.0 - rev / _GOLDEN_N        # least-violating = lowest rev

    space = SearchSpace(reserve=(0.0, 1.0))
    res = golden_engine.search(space, method="halving", budget=48,
                               constraints=(impossible,))
    assert not res.best_feasible
    # least violation = lowest revenue: the search is pushed to an edge
    assert min(res.best_point["reserve"], 1 - res.best_point["reserve"]) \
        < _R_TOL
    assert res.evaluations <= 48


def test_search_stops_at_budget_without_raising(golden_engine):
    """A budget too small to converge: the optimizer stops with what it
    has — converged=False, never BudgetExhausted out of the entry point,
    never an unaccounted sweep."""
    space = SearchSpace(reserve=(0.0, 1.0))
    res = golden_engine.search(space, method="halving", budget=17,
                               num_candidates=16)
    assert not res.converged
    assert res.evaluations == res.ledger.spent <= 17


def test_search_rejects_unknown_method_and_objective(golden_engine):
    space = SearchSpace(reserve=(0.0, 1.0))
    with pytest.raises(ValueError, match="unknown search method"):
        golden_engine.search(space, method="anneal")
    with pytest.raises(ValueError, match="unknown objective"):
        golden_engine.search(space, objective="profit")


def test_hillclimb_init_and_trajectory(golden_engine):
    """Hill-climb from a poor corner still reaches r*; the trajectory log
    carries per-batch notes and the formatted table renders."""
    space = SearchSpace(reserve=(0.0, 1.0))
    res = golden_engine.search(space, method="hillclimb", budget=64,
                               init={"reserve": 0.05})
    assert abs(res.best_point["reserve"] - _R_STAR) < _R_TOL
    assert res.history[0]["note"] == "hillclimb init"
    assert any(h.get("moved") for h in res.history[1:])
    table = res.format_trajectory()
    assert "hillclimb init" in table and "total:" in table


def test_optimizers_are_deterministic(golden_engine):
    """No RNG anywhere: the same search run twice gives the identical
    trajectory (points, values, ledger trail)."""
    space = SearchSpace(reserve=(0.0, 1.0))
    a = golden_engine.search(space, method="halving", budget=48)
    b = golden_engine.search(space, method="halving", budget=48)
    assert a.best_point == b.best_point
    assert a.best_value == b.best_value
    assert [h["points"] for h in a.history] == \
        [h["points"] for h in b.history]
    assert a.ledger.entries == b.ledger.entries


def test_direct_optimizer_api_with_synthetic_objective():
    """The optimizers are engine-independent: drive them with a plain
    callback (paraboloid with a feasibility cut) and check both respect
    the charge-before-evaluate contract."""
    space = SearchSpace(bid_scale=(0.0, 2.0))
    calls = []

    def evaluate(points, note):
        calls.append((note, len(points)))
        xs = np.array([p["bid_scale"] for p in points])
        return -(xs - 1.3) ** 2, np.where(xs <= 1.8, 0.0, -1.0)

    led = EvaluationLedger(budget=200)
    res = successive_halving(evaluate, space, led)
    assert abs(res.best_point["bid_scale"] - 1.3) < 0.02
    assert sum(n for _, n in calls) == led.spent == res.evaluations

    led2 = EvaluationLedger(budget=200)
    res2 = coordinate_hillclimb(evaluate, space, led2,
                                init={"bid_scale": 0.2})
    assert abs(res2.best_point["bid_scale"] - 1.3) < 0.02
    assert res2.converged
