"""Algorithm 2 (parallel simulation) vs the sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import parallel_simulate, sequential_replay
from repro.core import theory
from repro.data import make_synthetic_env


@pytest.fixture(scope="module")
def env():
    return make_synthetic_env(jax.random.PRNGKey(1), n_events=8192,
                              n_campaigns=32, emb_dim=8)


def test_parallel_close_to_oracle(env):
    ref = sequential_replay(env.values, env.budgets, env.rule)
    par = parallel_simulate(env.values, env.budgets, env.rule)
    rel = np.abs(np.asarray(par.final_spend) - np.asarray(ref.final_spend)) \
        / np.maximum(np.asarray(ref.final_spend), 1e-9)
    assert rel.mean() < 0.08, rel.mean()
    # cap-out count agrees closely
    n_ref = int((np.asarray(ref.cap_times) <= env.n_events).sum())
    n_par = int((np.asarray(par.cap_times) <= env.n_events).sum())
    assert abs(n_ref - n_par) <= 3


def test_parallel_rounds_bounded_by_capouts(env):
    ref = sequential_replay(env.values, env.budgets, env.rule)
    _, trace = parallel_simulate(env.values, env.budgets, env.rule,
                                 return_trace=True)
    n_capped = int((np.asarray(ref.cap_times) <= env.n_events).sum())
    # K cap-outs => at most K+1 parallel rounds (the paper's serial depth)
    assert trace.num_rounds <= n_capped + 2


def test_no_budgets_reduces_to_plain_sum(env):
    """With infinite budgets Algorithm 2 degenerates to Algorithm 1: one
    round, exact order-free sum."""
    from repro.core import auction, spend_sums
    inf_b = jnp.full_like(env.budgets, jnp.inf)
    par, trace = parallel_simulate(env.values, inf_b, env.rule,
                                   return_trace=True)
    assert trace.num_rounds == 1
    w, p = auction.resolve(env.values,
                           jnp.ones((env.n_campaigns,), bool), env.rule)
    exact = spend_sums(w, p, env.n_campaigns)
    np.testing.assert_allclose(np.asarray(par.final_spend),
                               np.asarray(exact), rtol=1e-4)


def test_error_within_theorem52_style_bound(env):
    """The observed error should sit under a (loose) Thm-5.2 envelope with
    empirical constants."""
    ref = sequential_replay(env.values, env.budgets, env.rule)
    par = parallel_simulate(env.values, env.budgets, env.rule)
    err = float(jnp.max(jnp.abs(par.final_spend - ref.final_spend)))
    c_const = theory.estimate_c_const(env.values, env.rule)
    k = int((np.asarray(ref.cap_times) <= env.n_events).sum())
    gamma = 1.0      # first price upper bound from the paper
    # t chosen at the 1e-2 failure level of Lemma 5.1
    t = np.sqrt(np.log(2 / 1e-2) * c_const**2 / (2 * env.n_events))
    bound = theory.thm52_bound(k, gamma, eps=0.0, c_const=c_const,
                               n_events=env.n_events, t=t)
    assert err <= bound, (err, bound)
