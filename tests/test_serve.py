"""Serving engine + budget-capped (burnout-variable) batch scheduling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import build_model
from repro.serve.engine import (ServeEngine, estimate_exit_steps,
                                plan_compactions, wasted_slot_steps)


def test_generate_shapes(rng_key):
    cfg = reduced_config("stablelm-1.6b")
    model = build_model(cfg)
    params = model.init_params(rng_key)
    eng = ServeEngine(model, params, max_len=32)
    batch = {"tokens": jax.random.randint(rng_key, (2, 8), 0,
                                          cfg.vocab_size)}
    toks = eng.generate(batch, num_steps=6)
    assert toks.shape == (2, 6)
    assert ((np.asarray(toks) >= 0)
            & (np.asarray(toks) < cfg.vocab_size)).all()


def test_generate_deterministic_greedy(rng_key):
    cfg = reduced_config("xlstm-125m")
    model = build_model(cfg)
    params = model.init_params(rng_key)
    eng = ServeEngine(model, params, max_len=32)
    batch = {"tokens": jax.random.randint(rng_key, (1, 8), 0,
                                          cfg.vocab_size)}
    a = eng.generate(batch, num_steps=5)
    b = eng.generate(batch, num_steps=5)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_exit_estimates_monotone_in_budget():
    budgets = np.asarray([10, 50, 200, 1000])
    est = estimate_exit_steps(budgets, eos_survival=0.99)
    assert (np.diff(est) > 0).all()
    assert (est <= budgets + 1e-6).all()


def test_compaction_plan_reduces_waste():
    """SORT2AGGREGATE-style static compaction beats a single fixed batch."""
    rng = np.random.default_rng(0)
    budgets = rng.integers(16, 512, size=64)
    exits = np.minimum(budgets, rng.geometric(1 / 200.0, size=64))
    plan = plan_compactions(exits.astype(np.float64), max_segments=4,
                            total_steps=int(exits.max()))
    naive = plan_compactions(exits.astype(np.float64), max_segments=1,
                             total_steps=int(exits.max()))
    w_plan = wasted_slot_steps(plan, exits)
    w_naive = wasted_slot_steps(naive, exits)
    assert w_plan < w_naive * 0.6, (w_plan, w_naive)


def test_plan_segments_partition_horizon():
    exits = np.asarray([10.0, 20.0, 30.0, 40.0])
    plan = plan_compactions(exits, max_segments=3, total_steps=40)
    starts = [s for s, _, _ in plan.segments]
    ends = [e for _, e, _ in plan.segments]
    assert starts[0] == 0 and ends[-1] == 40
    assert starts[1:] == ends[:-1]


def test_empty_batch():
    """A drained queue (zero requests) plans to an empty, waste-free
    schedule instead of crashing on max() of an empty array."""
    est = estimate_exit_steps(np.zeros((0,), np.int64))
    assert est.shape == (0,)
    plan = plan_compactions(est)
    assert plan.compaction_points == [] and plan.segments == []
    assert wasted_slot_steps(plan, np.zeros((0,), np.int64)) == 0


def test_queue_drain_ordering():
    """Compaction points are sorted ascending and each segment's planned
    live count is the number of requests whose estimated exit lies past the
    segment start — so live counts drain monotonically as the batch
    empties, and the segments partition the horizon."""
    rng = np.random.default_rng(7)
    exits = rng.integers(5, 300, size=48).astype(np.float64)
    total = int(exits.max())
    plan = plan_compactions(exits, max_segments=5, total_steps=total)
    assert plan.compaction_points == sorted(plan.compaction_points)
    assert len(set(plan.compaction_points)) == len(plan.compaction_points)
    lives = [live for _, _, live in plan.segments]
    assert lives == sorted(lives, reverse=True)
    for start, _, live in plan.segments:
        assert live == int((exits > start).sum())
    starts = [s for s, _, _ in plan.segments]
    ends = [e for _, e, _ in plan.segments]
    assert starts[0] == 0 and ends[-1] == total
    assert starts[1:] == ends[:-1]


def test_single_request_plan():
    plan = plan_compactions(np.asarray([17.0]), max_segments=4)
    assert plan.segments == [(0, 17, 1)]
    assert plan.compaction_points == []


def _wasted_loop(plan, true_exits):
    """The original O(B*T) per-step recount — the vectorized
    wasted_slot_steps must reproduce it exactly."""
    waste = 0
    for start, end, live in plan.segments:
        for t in range(start, end):
            active = int((true_exits > t).sum())
            waste += max(live - active, 0)
    return waste


def test_wasted_slot_steps_matches_loop_reference():
    """The searchsorted vectorization is golden against the per-step loop
    across random batches, including float exits, ties, and misestimates
    in both directions."""
    rng = np.random.default_rng(11)
    for trial in range(20):
        b = int(rng.integers(1, 40))
        exits = rng.integers(1, 120, size=b).astype(np.float64)
        if trial % 3 == 0:
            exits += rng.uniform(0.0, 0.9, size=b)  # fractional exits
        est = exits * rng.uniform(0.6, 1.5, size=b)  # misestimated plan
        total = int(max(exits.max(), est.max())) + 1
        plan = plan_compactions(est, max_segments=int(rng.integers(1, 6)),
                                total_steps=total)
        assert wasted_slot_steps(plan, exits) == _wasted_loop(plan, exits), \
            f"trial {trial}"


def test_wasted_slot_steps_edge_cases():
    assert wasted_slot_steps(plan_compactions(np.zeros((0,))),
                             np.zeros((0,))) == 0
    # single request, exact estimate: zero waste
    plan = plan_compactions(np.asarray([10.0]), max_segments=3)
    assert wasted_slot_steps(plan, np.asarray([10.0])) == 0
    assert wasted_slot_steps(plan, np.asarray([10.0])) == \
        _wasted_loop(plan, np.asarray([10.0]))
    # all-tied exits collapse to one segment; early true exits leak waste
    tied = np.full((6,), 20.0)
    plan = plan_compactions(tied, max_segments=4, total_steps=20)
    early = np.full((6,), 5.0)
    assert wasted_slot_steps(plan, early) == _wasted_loop(plan, early) > 0


def test_plan_compactions_invariants():
    """Structural invariants for any input: segments tile [0, total),
    live counts equal the planned survivor count at each segment start and
    never increase, and the segment count respects max_segments."""
    rng = np.random.default_rng(13)
    for trial in range(20):
        b = int(rng.integers(1, 60))
        exits = rng.integers(1, 400, size=b).astype(np.float64)
        max_segments = int(rng.integers(1, 7))
        total = int(exits.max())
        plan = plan_compactions(exits, max_segments=max_segments,
                                total_steps=total)
        starts = [s for s, _, _ in plan.segments]
        ends = [e for _, e, _ in plan.segments]
        assert starts[0] == 0 and ends[-1] == total, f"trial {trial}"
        assert starts[1:] == ends[:-1], f"trial {trial}"
        assert len(plan.segments) <= max_segments, f"trial {trial}"
        lives = [live for _, _, live in plan.segments]
        assert lives == sorted(lives, reverse=True), f"trial {trial}"
        for start, _, live in plan.segments:
            assert live == int((exits > start).sum()), f"trial {trial}"
        assert plan.compaction_points == sorted(plan.compaction_points)
        assert all(p > 0 for p in plan.compaction_points)
