"""The structural HLO cost analyzer vs XLA's cost_analysis.

XLA counts while-loop bodies once (demonstrated here); our analyzer scales by
trip counts and must agree with XLA on loop-free programs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import compiled_cost_analysis
from repro.launch import hlo_cost


def _mm(x, w):
    return jnp.tanh(x @ w)


def test_matches_xla_on_straightline():
    x = jnp.ones((256, 256))
    w = jnp.ones((256, 256))
    c = jax.jit(lambda x, w: _mm(_mm(x, w), w)).lower(x, w).compile()
    mine = hlo_cost.analyze(c.as_text())
    xla = compiled_cost_analysis(c)
    assert abs(mine.flops - xla["flops"]) / xla["flops"] < 0.02
    assert abs(mine.bytes - xla["bytes accessed"]) / xla["bytes accessed"] < 0.3


def test_scan_trip_count_scaling():
    x = jnp.ones((512, 512))
    w = jnp.ones((512, 512))

    def scanned(x, w):
        def body(c, _):
            return _mm(c, w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c = jax.jit(scanned).lower(x, w).compile()
    xla = compiled_cost_analysis(c)["flops"]
    mine = hlo_cost.analyze(c.as_text()).flops
    true = 10 * 2 * 512 ** 3
    # XLA undercounts ~10x; ours within 2% of the truth
    assert xla < true / 5
    assert abs(mine - true) / true < 0.02


def test_nested_scan():
    x = jnp.ones((256, 256))
    w = jnp.ones((256, 256))

    def nested(x, w):
        def outer(c, _):
            def inner(d, _):
                return _mm(d, w), None
            d, _ = jax.lax.scan(inner, c, None, length=5)
            return d, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    c = jax.jit(nested).lower(x, w).compile()
    mine = hlo_cost.analyze(c.as_text()).flops
    true = 20 * 2 * 256 ** 3
    assert abs(mine - true) / true < 0.03


def test_collective_parsing():
    from jax.sharding import PartitionSpec as P
    import functools
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (run in the dryrun subprocess tests)")


def test_dtype_bytes_table():
    assert hlo_cost._type_bytes("f32[128,256]") == 128 * 256 * 4
    assert hlo_cost._type_bytes("bf16[8]{0}") == 16
    assert hlo_cost._type_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert hlo_cost._type_bytes("pred[]") == 1
