"""Multi-slot auctions (paper §8 extension): same burnout machinery, S
winners per event."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.multislot import (MultiSlotRule, aggregate_multislot,
                                  refine_segments_multislot,
                                  resolve_multislot,
                                  sequential_replay_multislot,
                                  spend_sums_multislot)
from repro.core.types import Segments
from repro.data import make_synthetic_env


@pytest.fixture(scope="module")
def env():
    return make_synthetic_env(jax.random.PRNGKey(6), n_events=4096,
                              n_campaigns=24, emb_dim=8)


def test_resolve_slots_are_distinct_and_ordered(env):
    rule = MultiSlotRule.first_price(env.n_campaigns, slots=3)
    w, p = resolve_multislot(env.values[:256],
                             jnp.ones((env.n_campaigns,), bool), rule)
    w_np, p_np = np.asarray(w), np.asarray(p)
    for row_w in w_np:                       # no campaign wins two slots
        filled = row_w[row_w >= 0]
        assert len(set(filled.tolist())) == len(filled)
    # discounted prices are non-increasing across slots (values <= 1 each)
    assert (np.diff(p_np, axis=1) <= 1e-6).all()


def test_single_slot_reduces_to_base_auction(env):
    from repro.core import auction
    rule = MultiSlotRule.first_price(env.n_campaigns, slots=1)
    w1, p1 = resolve_multislot(env.values,
                               jnp.ones((env.n_campaigns,), bool), rule)
    w2, p2 = auction.resolve(env.values,
                             jnp.ones((env.n_campaigns,), bool), rule.base)
    assert np.array_equal(np.asarray(w1[:, 0]), np.asarray(w2))
    np.testing.assert_allclose(np.asarray(p1[:, 0]), np.asarray(p2),
                               rtol=1e-6)


def test_oracle_burnout_invariants(env):
    rule = MultiSlotRule.first_price(env.n_campaigns, slots=3)
    res = sequential_replay_multislot(env.values, env.budgets, rule)
    # overshoot bounded by S * max single increment (Asm 3.2 margin)
    overshoot = np.asarray(res.final_spend - env.budgets)
    assert (overshoot <= 3 * float(env.values.max()) + 1e-5).all()
    # irreversibility: no wins after cap
    w = np.asarray(res.winners)                 # (N, S)
    cap = np.asarray(res.cap_times)
    for c in range(env.n_campaigns):
        if cap[c] <= env.n_events:
            assert not (w[cap[c]:] == c).any()


def test_aggregate_at_oracle_caps_matches(env):
    rule = MultiSlotRule.first_price(env.n_campaigns, slots=3)
    ref = sequential_replay_multislot(env.values, env.budgets, rule)
    segs = Segments.from_cap_times(ref.cap_times, env.n_events)
    rep = aggregate_multislot(env.values, segs, env.budgets, rule)
    np.testing.assert_allclose(np.asarray(rep.final_spend),
                               np.asarray(ref.final_spend), rtol=2e-3,
                               atol=2e-3)


def test_sort2aggregate_playbook_on_multislot(env):
    """Warm-started refine + aggregate tracks the multi-slot oracle."""
    rule = MultiSlotRule.first_price(env.n_campaigns, slots=3)
    ref = sequential_replay_multislot(env.values, env.budgets, rule)
    noisy = np.clip(np.asarray(ref.cap_times)
                    + np.random.default_rng(0).integers(-150, 150,
                                                        env.n_campaigns),
                    1, env.n_events + 1)
    caps, iters, converged = refine_segments_multislot(
        env.values, env.budgets, rule, jnp.asarray(noisy, jnp.int32))
    segs = Segments.from_cap_times(caps, env.n_events)
    rep = aggregate_multislot(env.values, segs, env.budgets, rule)
    rel = np.abs(np.asarray(rep.final_spend)
                 - np.asarray(ref.final_spend)) \
        / np.maximum(np.asarray(ref.final_spend), 1e-9)
    assert rel.mean() < 0.05, (rel.mean(), iters, converged)


def test_multislot_revenue_is_scalar(env):
    """SimResult.revenue must reduce (N, S) multislot prices to a scalar
    (regression: a batched-sweep-aware .sum(-1) once returned (N,))."""
    rule = MultiSlotRule.first_price(env.n_campaigns, slots=2)
    res = sequential_replay_multislot(env.values, env.budgets, rule)
    assert res.prices.ndim == 2
    rev = float(res.revenue)          # raises if revenue is not 0-D
    assert rev == pytest.approx(float(np.asarray(res.prices).sum()))
