"""Hypothesis-randomized metamorphic tests for the scenario CRN contract.

The deterministic panel versions of these live in tests/test_scenarios.py;
here the intervention specs themselves are drawn by hypothesis, so the
metamorphics are exercised over random compositions of pause / boost /
pacing / noise / participation interventions and random chunk schedules.
CI runs this module under the forced multi-device step too (the sweeps
pick up however many devices are visible).
"""
import functools

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import numpy as np

from repro.core import AuctionRule, CounterfactualEngine
from repro.scenarios import (BidNoise, BoostCampaign, BudgetPacing,
                             ParticipationJitter, PauseCampaign,
                             compile_family)

settings.register_profile("ci", deadline=None, max_examples=25,
                          derandomize=True)
settings.load_profile("ci")

N, C = 512, 8


@functools.lru_cache(maxsize=1)
def _env():
    from repro.data import make_synthetic_env
    return make_synthetic_env(jax.random.PRNGKey(3), n_events=N,
                              n_campaigns=C, emb_dim=6)


def _engine():
    env = _env()
    return CounterfactualEngine(env.values, env.budgets,
                                AuctionRule.first_price(C))


def _spends_caps(swept):
    return (np.asarray(swept.results.final_spend),
            np.asarray(swept.results.cap_times))


def _intervention_strategy():
    pause = st.builds(PauseCampaign, st.integers(0, C - 1))
    boost = st.builds(BoostCampaign, st.integers(0, C - 1),
                      st.floats(0.5, 2.5))
    pacing = st.builds(
        lambda c, a, w: BudgetPacing(c, start=a, stop=min(a + w, N)),
        st.integers(0, C - 1), st.integers(0, N - 1), st.integers(1, N))
    noise = st.builds(BidNoise, st.floats(0.0, 0.5),
                      st.one_of(st.none(), st.integers(0, C - 1)))
    part = st.builds(ParticipationJitter, st.floats(0.5, 1.0),
                     st.one_of(st.none(), st.integers(0, C - 1)))
    return st.lists(st.one_of(pause, boost, pacing, noise, part),
                    min_size=1, max_size=3).map(tuple)


@given(_intervention_strategy(), st.sampled_from([None, 64, 128]),
       st.sampled_from([None, 1, 3]))
def test_crn_identical_specs_identical_lanes_any_chunking(spec, epc, spc):
    """The SAME random intervention spec in two different lanes produces
    bitwise identical outcomes, and the whole family is bitwise invariant
    under every aligned event/scenario chunk schedule."""
    eng = _engine()
    fam = compile_family(eng.values, eng.budgets, eng.base_rule,
                         [spec, spec], key=jax.random.PRNGKey(5))
    spend, caps = _spends_caps(eng.sweep(fam))
    np.testing.assert_array_equal(spend[2], spend[1])
    np.testing.assert_array_equal(caps[2], caps[1])
    out = eng.sweep(fam, chunks=epc, scenario_chunks=spc)
    np.testing.assert_array_equal(np.asarray(out.results.final_spend),
                                  spend, err_msg=f"epc={epc} spc={spc}")
    np.testing.assert_array_equal(np.asarray(out.results.cap_times),
                                  caps, err_msg=f"epc={epc} spc={spc}")


@given(_intervention_strategy(), _intervention_strategy())
def test_crn_delta_isolation_across_family_membership(spec_a, spec_b):
    """Adding a random scenario to a family never changes any other lane's
    bits: outcomes depend only on (family key, own interventions), so
    deltas isolate the intervention by construction."""
    eng = _engine()
    key = jax.random.PRNGKey(5)
    fam_a = compile_family(eng.values, eng.budgets, eng.base_rule,
                           [spec_a], key=key)
    fam_ab = compile_family(eng.values, eng.budgets, eng.base_rule,
                            [spec_a, spec_b], key=key)
    sp_a, ct_a = _spends_caps(eng.sweep(fam_a))
    sp_ab, ct_ab = _spends_caps(eng.sweep(fam_ab))
    np.testing.assert_array_equal(sp_ab[:2], sp_a)
    np.testing.assert_array_equal(ct_ab[:2], ct_a)


@given(_intervention_strategy(), _intervention_strategy())
def test_crn_scenario_order_independence(spec_a, spec_b):
    """Permuting the scenario list permutes the results bitwise — lane
    outcomes carry no trace of their scenario index."""
    eng = _engine()
    key = jax.random.PRNGKey(5)
    ab = compile_family(eng.values, eng.budgets, eng.base_rule,
                        [spec_a, spec_b], key=key)
    ba = compile_family(eng.values, eng.budgets, eng.base_rule,
                        [spec_b, spec_a], key=key)
    sp_ab, ct_ab = _spends_caps(eng.sweep(ab))
    sp_ba, ct_ba = _spends_caps(eng.sweep(ba))
    np.testing.assert_array_equal(sp_ab[1], sp_ba[2])
    np.testing.assert_array_equal(sp_ab[2], sp_ba[1])
    np.testing.assert_array_equal(ct_ab[1], ct_ba[2])


@given(st.integers(0, C - 1), _intervention_strategy())
def test_pause_property(c, extra):
    """PauseCampaign(c) composed with ANY random interventions: campaign c
    spends exactly 0 and never caps out."""
    eng = _engine()
    fam = compile_family(eng.values, eng.budgets, eng.base_rule,
                         [tuple(extra) + (PauseCampaign(c),)],
                         key=jax.random.PRNGKey(5))
    spend, caps = _spends_caps(eng.sweep(fam))
    assert spend[1, c] == 0.0
    assert caps[1, c] == N + 1
