"""Multi-device core algorithms (8 fake CPU devices via subprocess).

The sharded drivers (shard_map + psum over the event axis) must reproduce the
single-process results. Runs in a subprocess because the device count is
fixed at first jax init.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 8
    from repro.data import make_synthetic_env
    from repro.core import sequential_replay, parallel_simulate, Segments
    from repro.core import sharded as sh

    env = make_synthetic_env(jax.random.PRNGKey(0), n_events=8192,
                             n_campaigns=24, emb_dim=8)
    ref = sequential_replay(env.values, env.budgets, env.rule)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("data",))
    vals = sh.shard_events(env.values, mesh)

    # Algorithm 2 with mesh-sharded reductions == single-process Algorithm 2,
    # bit-for-bit: the closures reduce on the canonical block grid and this
    # mesh is aligned (shards of 1024 = whole blocks of 256)
    rate_fn, block_fn = sh.make_sharded_kernels(mesh, env.rule)
    par_sh = parallel_simulate(env.values, env.budgets, env.rule,
                               rate_fn=rate_fn(vals), block_fn=block_fn(vals))
    par_1p = parallel_simulate(env.values, env.budgets, env.rule)
    assert np.array_equal(np.asarray(par_sh.final_spend),
                          np.asarray(par_1p.final_spend))
    assert np.array_equal(np.asarray(par_sh.cap_times),
                          np.asarray(par_1p.cap_times))

    # sharded aggregate at oracle caps == oracle
    segs = Segments.from_cap_times(ref.cap_times, env.n_events)
    agg = sh.sharded_aggregate(mesh, vals, segs, env.budgets, env.rule)
    np.testing.assert_allclose(np.asarray(agg.final_spend),
                               np.asarray(ref.final_spend), rtol=1e-3,
                               atol=1e-3)
    assert np.array_equal(np.asarray(agg.cap_times),
                          np.asarray(ref.cap_times))

    # sharded VI converges toward cap fractions
    pi = sh.estimate_pi_sharded(mesh, vals, env.budgets, env.rule,
                                jax.random.PRNGKey(3), num_iters=400,
                                local_batch=16, eta=0.5, eta_decay=0.01)
    frac = np.minimum(np.asarray(ref.cap_times) / env.n_events, 1.0)
    mae = float(np.abs(np.asarray(pi) - frac).mean())
    assert mae < 0.08, mae
    print("SHARDED_OK", mae)

    # mesh-batched scenario sweep == single-device batched loop, bit-for-bit,
    # on an 8-way event mesh AND a 4(event)x2(scenario) mesh
    from repro.core import ScenarioGrid, sweep_state_machine
    from repro.core.sharded import sweep_sharded
    from repro.launch.mesh import SweepMeshSpec
    grid = ScenarioGrid.product(env.rule, env.budgets,
                                bid_scales=[1.0, 0.9, 1.2],
                                budget_scales=[1.0, 0.5])
    sw_ref = sweep_state_machine(env.values, grid.budgets, grid.rules,
                                 resolve="jnp")
    for spec in [SweepMeshSpec.for_devices(),
                 SweepMeshSpec.for_devices(num_event_devices=4,
                                           num_scenario_devices=2)]:
        for resolve in ("jnp", "fused"):
            out = sweep_sharded(env.values, grid.budgets, grid.rules, spec,
                                resolve=resolve)
            for name, a, b in zip(("s_hat", "cap", "retired", "bnds", "rnd",
                                   "n_hat"), out, sw_ref):
                assert np.array_equal(np.asarray(a), np.asarray(b)), \\
                    (resolve, spec.event_axes, spec.scenario_axis, name)
    print("SWEEP_SHARDED_OK")
""")


@pytest.mark.slow
def test_sharded_core_on_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED_OK" in out.stdout
    assert "SWEEP_SHARDED_OK" in out.stdout
